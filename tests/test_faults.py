"""Fault injection + failure-hardened switching.

Covers the chaos subsystem end to end at unit scale: seeded injector
determinism, retry backoff properties, the distinct build-callback
failure category, the dead-link guard and outage->recovery monitoring,
the circuit breaker, watchdog abort + rollback, edge-only degraded
mode, and the hand-off integrity envelope (detection, stale-epoch
rejection, recompute fallback) on a real tiny model.
"""
import dataclasses
import math
import warnings

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    from _hypothesis_compat import hypothesis, st

from repro.configs import get_config
from repro.core import (BackgroundBuildFailed, BandwidthTrace,
                        BuildCallbackFailed, BuildExecutor, CircuitBreaker,
                        HandoffCorrupted, InjectedBuildFailure, NetworkModel,
                        NetworkMonitor, PipelineManager, RetryPolicy,
                        SwitchAbortedWarning, faults, make_stateful_manager,
                        payload_checksum)
from repro.core.executor import BuildHandle
from repro.core.faults import (BuildFail, LinkOutage, SlowCloud,
                               _keyed_uniform)
from repro.core.stateful import HANDOFF_META_KEY, HandoffIntegrityWarning
from repro.serving import ServingEngine, VirtualClock, request_stream
from repro.serving.sim import SimPool, SimRunner


# ---------------------------------------------------------------------------
# network guards: dead link, outage -> recovery flap
# ---------------------------------------------------------------------------

def test_dead_link_prices_as_inf_not_crash():
    assert NetworkModel(0.0).transfer_time(1000) == math.inf
    assert NetworkModel(-3.0).transfer_time(1) == math.inf
    assert math.isfinite(NetworkModel(20.0).transfer_time(1000))


def test_monitor_survives_outage_then_recovery_flap():
    """A trace step to 0 Mbps and back must read as two detected changes,
    not a ZeroDivisionError on the relative-change test."""
    trace = BandwidthTrace(steps=[(0.0, 20.0), (2.0, 0.0), (4.0, 20.0)])
    mon = NetworkMonitor(trace)
    assert mon.poll(0.0) is None            # first sample primes
    assert mon.poll(1.0) is None
    outage = mon.poll(2.5)
    assert outage is not None and outage.bandwidth_mbps == 0.0
    assert mon.poll(3.0) is None            # still dark: no new change
    recovery = mon.poll(4.5)                # rel change from 0 is infinite
    assert recovery is not None and recovery.bandwidth_mbps == 20.0
    assert mon.poll(5.0) is None


def test_circuit_breaker_is_edge_triggered():
    br = CircuitBreaker(open_after=2, close_after=1)
    assert br.record(0.0, 0.0) is None      # one bad sample: not yet
    assert br.record(1.0, 0.0) == "open"
    assert br.is_open and br.opened_at == 1.0
    assert br.record(2.0, 0.0) is None      # already open: no re-edge
    assert br.record(3.0, 20.0) == "close"
    assert not br.is_open
    assert br.record(4.0, 20.0) is None


# ---------------------------------------------------------------------------
# retry policy: backoff properties (hypothesis)
# ---------------------------------------------------------------------------

@hypothesis.given(st.integers(0, 10_000), st.floats(0.001, 0.2),
                  st.floats(1.5, 3.0), st.floats(0.05, 1.0),
                  st.floats(0.0, 0.5))
@hypothesis.settings(deadline=None, max_examples=30)
def test_backoff_monotone_capped_seed_identical(seed, base, factor, cap,
                                                jitter):
    p = RetryPolicy(max_attempts=7, base_s=base, factor=factor, cap_s=cap,
                    jitter=jitter, seed=seed)
    sched = p.schedule()
    assert len(sched) == 6
    assert all(0.0 <= d <= cap + 1e-12 for d in sched)
    # factor >= 1 + jitter makes the pre-cap schedule monotone, and
    # min(cap, .) preserves that
    assert all(a <= b + 1e-12 for a, b in zip(sched, sched[1:]))
    twin = RetryPolicy(max_attempts=7, base_s=base, factor=factor,
                       cap_s=cap, jitter=jitter, seed=seed)
    assert twin.schedule() == sched          # keyed jitter: byte-identical


def test_retry_policy_rejects_non_monotone_params():
    with pytest.raises(ValueError):
        RetryPolicy(factor=1.0, jitter=0.5)  # jittered draw could shrink
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=-0.1)


# ---------------------------------------------------------------------------
# build handle: retries, deadline, callback failure category
# ---------------------------------------------------------------------------

def _flaky(fail_times):
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] <= fail_times:
            raise RuntimeError(f"transient #{calls['n']}")
        return "built"
    return fn


def test_retry_redeems_transient_build_failure():
    h = BuildHandle(_flaky(2), retry=RetryPolicy(max_attempts=3,
                                                 base_s=0.001, cap_s=0.01))
    h._run()
    assert h.attempts == 3
    assert h.error is None and h.result == "built"


def test_retry_exhaustion_surfaces_last_error():
    h = BuildHandle(_flaky(10), retry=RetryPolicy(max_attempts=2,
                                                  base_s=0.001, cap_s=0.01))
    h._run()
    assert h.attempts == 2
    assert h.failed and "transient #2" in str(h.error)


def test_retry_deadline_abandons_early():
    # backoff of ~10 s would land far past the 1 ms deadline: one attempt
    h = BuildHandle(_flaky(10), retry=RetryPolicy(
        max_attempts=5, base_s=10.0, cap_s=10.0, deadline_s=0.001))
    h._run()
    assert h.attempts == 1 and h.failed


def test_callback_failure_is_a_distinct_category():
    assert not issubclass(BuildCallbackFailed, BackgroundBuildFailed)

    def bad_cb(handle):
        raise RuntimeError("boom in callback")

    h = BuildHandle(lambda: 42)
    h.add_done_callback(bad_cb)
    with pytest.warns(BuildCallbackFailed):
        h._run()
    # the BUILD succeeded; only the callback failed
    assert h.error is None and h.result == 42 and h.done


def test_executor_stamps_default_retry_policy():
    ex = BuildExecutor(inline=True,
                       retry=RetryPolicy(max_attempts=3, base_s=0.001,
                                         cap_s=0.01))
    h = ex.submit(_flaky(1))
    assert h.attempts == 2 and h.result == "built"
    ex.shutdown()


# ---------------------------------------------------------------------------
# fault plans: spec parsing, keyed determinism, arming
# ---------------------------------------------------------------------------

def test_faults_spec_parsing_and_registry():
    plan = faults("build_fail(p=0.3)+link_outage(at=1,dur=2)"
                  "+slow_cloud(factor=2.0)", seed=7)
    assert [type(i) for i in plan.injectors] == [BuildFail, LinkOutage,
                                                 SlowCloud]
    assert [i.index for i in plan.injectors] == [0, 1, 2]
    assert all(i.plan is plan for i in plan.injectors)
    assert faults("").injectors == ()        # inert control plan
    with pytest.raises((KeyError, ValueError)):
        faults("no_such_fault(p=1)")
    with pytest.raises(ValueError):
        faults("handoff_corrupt(mode='sideways')")


def test_keyed_draws_are_site_stable():
    assert _keyed_uniform(3, 1, "build", (2, True), 1) == \
        _keyed_uniform(3, 1, "build", (2, True), 1)
    assert _keyed_uniform(3, 1, "build", (2, True), 1) != \
        _keyed_uniform(3, 2, "build", (2, True), 1)
    a = faults("build_fail(p=0.5)", seed=11)
    b = faults("build_fail(p=0.5)", seed=11)
    hits = [a.injectors[0]._hit(("k", False), n) for n in range(32)]
    assert hits == [b.injectors[0]._hit(("k", False), n) for n in range(32)]
    c = faults("build_fail(p=0.5)", seed=12)
    assert hits != [c.injectors[0]._hit(("k", False), n) for n in range(32)]


def test_plan_inert_until_armed():
    plan = faults("build_fail(p=1.0)")
    plan.on_build(("x", False))              # unarmed: no-op, not counted
    assert plan.build_attempts(("x", False)) == 0
    plan.arm()
    with pytest.raises(InjectedBuildFailure):
        plan.on_build(("x", False))
    assert plan.build_attempts(("x", False)) == 1
    assert any("build_fail" in e for e in plan.event_log())
    plan.disarm()
    plan.on_build(("x", False))              # valve closed again
    assert plan.build_attempts(("x", False)) == 1


def test_link_outage_overlays_trace():
    plan = faults("link_outage(at=2.0,dur=2.0)")
    trace = plan.apply_to_trace(BandwidthTrace(steps=[(0.0, 20.0)]))
    assert trace.at(1.0).bandwidth_mbps == 20.0
    assert trace.at(2.0).bandwidth_mbps == 0.0
    assert trace.at(3.9).bandwidth_mbps == 0.0
    assert trace.at(4.0).bandwidth_mbps == 20.0
    assert set(trace.change_points()) == {2.0, 4.0}


def _fake_payload():
    arr = np.arange(8, dtype=np.float32)
    payload = {"layer0": (str(arr.dtype), arr.shape, arr.tobytes())}
    payload[HANDOFF_META_KEY] = (0, 8, payload_checksum(payload))
    return payload


@pytest.mark.parametrize("mode", ["flip", "truncate"])
def test_handoff_corrupt_breaks_checksum_not_envelope(mode):
    payload = _fake_payload()
    crc_before = payload[HANDOFF_META_KEY][2]
    plan = faults(f"handoff_corrupt(p=1.0,mode='{mode}')").arm()
    plan.mutate_handoff(payload, epoch=0)
    # the envelope survives intact (else the mismatch could not be
    # DETECTED), while the tensor bytes no longer match it
    assert payload[HANDOFF_META_KEY][2] == crc_before
    assert payload_checksum(payload) != crc_before
    buf = payload["layer0"][2]
    assert len(buf) == (16 if mode == "truncate" else 32)


# ---------------------------------------------------------------------------
# engine-level hardening (SimPool: real control plane, analytic pricing)
# ---------------------------------------------------------------------------

def _sim_engine(plan, *, split=2, standby_split=None, timeout=0.3,
                breaker=None, mem_mult=2.0, executor=None):
    runner = SimRunner(4)
    net = NetworkModel(20.0)
    budget = int(runner.edge_param_bytes(runner.max_split) * mem_mult)
    pool = SimPool(runner, net, fault_plan=plan, mem_budget_bytes=budget,
                   executor=executor)
    mgr = PipelineManager(runner, split, net, None, pool=pool,
                          standby_split=standby_split)
    clock = VirtualClock(quantum=0.25)
    pool.sim_clock = clock
    eng = ServingEngine(mgr, clock=clock, switch_timeout_s=timeout,
                        breaker=breaker, fault_plan=plan)
    return mgr, pool, eng


def _teardown(plan, mgr):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plan.release()
        mgr.close()


def test_transient_build_failure_never_drops_a_request():
    """Regression: a build that fails once and then succeeds on retry must
    be invisible to the stream under switch_a — zero drops, zero aborts,
    the one injected failure redeemed on attempt 2."""
    plan = faults("build_fail(times=1)")
    ex = BuildExecutor(retry=RetryPolicy(max_attempts=3, base_s=0.01,
                                         cap_s=0.05))
    mgr, pool, eng = _sim_engine(plan, split=2, standby_split=3, timeout=1.0,
                                 executor=ex)
    plan.arm()
    eng.schedule_switch(1.0, "switch_a", 3)
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            tl = eng.run(request_stream({"x": 0}, fps=2.0, duration=4.0),
                         duration=4.0)
        assert not any(issubclass(w.category, BackgroundBuildFailed)
                       for w in caught), "retry did not redeem the failure"
        assert tl.dropped_count == 0
        assert tl.summary()["aborted_switches"] == 0
        assert tl.served_count > 0
        assert any("build_fail" in e for e in plan.event_log())
        # the standby rebuild hit the injected failure once, retried once
        assert plan.build_attempts((2, True)) == 2
    finally:
        _teardown(plan, mgr)


def test_watchdog_aborts_and_rolls_back_stalled_switch():
    plan = faults("build_stall(p=1.0)")
    mgr, pool, eng = _sim_engine(plan, split=1, timeout=0.2)
    plan.arm()
    eng.schedule_switch(1.0, "switch_b2", 3)
    try:
        with pytest.warns(SwitchAbortedWarning):
            tl = eng.run(request_stream({"x": 0}, fps=2.0, duration=3.0),
                         duration=3.0)
        assert len(tl.windows) == 1 and tl.windows[0].aborted
        active = pool.snapshot_active()
        assert active is not None and active.split == 1   # rolled back
        assert tl.served_count > 0 and tl.t_end >= 3.0    # never wedged
        assert eng.reports[0].aborted
    finally:
        _teardown(plan, mgr)


def test_degraded_mode_enters_and_recovers():
    plan = faults("")
    mgr, pool, eng = _sim_engine(plan, split=1,
                                 breaker=CircuitBreaker())
    eng.schedule_network(2.0, 0.0)           # outage
    eng.schedule_network(5.0, 20.0)          # recovery
    try:
        tl = eng.run(request_stream({"x": 0}, fps=2.0, duration=8.0),
                     duration=8.0)
        assert len(tl.degraded) == 1
        w = tl.degraded[0]
        assert w.closed and w.duration > 0
        assert tl.mttr() and tl.mttr() > 0
        assert any(r.degraded for r in tl.records if r.served)
        assert not any(r.drop_reason == "link_down" for r in tl.records)
        assert not eng.in_degraded
        active = pool.snapshot_active()
        assert active is not None and active.split == 1   # restored
    finally:
        _teardown(plan, mgr)


def test_pick_degraded_split_respects_memory_budget():
    runner = SimRunner(4)
    net = NetworkModel(20.0)
    plan = faults("")
    # budget fits the embedding + 2 layers: deepest edge-only split is 2
    pool = SimPool(runner, net,
                   mem_budget_bytes=runner.edge_param_bytes(2))
    mgr = PipelineManager(runner, 1, net, None, pool=pool)
    eng = ServingEngine(mgr, clock=VirtualClock(), breaker=CircuitBreaker())
    assert eng._pick_degraded_split() == 2
    mgr.close()
    # no budget: the whole model moves to the edge
    pool2 = SimPool(runner, net)
    mgr2 = PipelineManager(runner, 1, net, None, pool=pool2)
    eng2 = ServingEngine(mgr2, clock=VirtualClock(),
                         breaker=CircuitBreaker())
    assert eng2._pick_degraded_split() == runner.max_split
    mgr2.close()
    del plan


# ---------------------------------------------------------------------------
# hand-off integrity on a real (tiny) stateful model
# ---------------------------------------------------------------------------

def _tiny_stateful(**kw):
    cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(),
                              num_layers=2)
    return make_stateful_manager(cfg, split=1, net=NetworkModel(1000.0),
                                 prompt_len=8, max_seq=64, seed=0, **kw)


def test_corrupted_and_stale_payloads_rejected_state_untouched():
    mgr, session = _tiny_stateful()
    mgr.active.process()
    before = {k: np.asarray(v).copy() for k, v in session.cache.items()}

    # bit flip in one tensor: checksum mismatch, nothing committed
    payload, _ = session.export_layers(0, 2)
    victim = next(k for k in payload if k != HANDOFF_META_KEY)
    dtype, shape, buf = payload[victim]
    b = bytearray(buf)
    b[0] ^= 0xFF
    payload[victim] = (dtype, shape, bytes(b))
    with pytest.raises(HandoffCorrupted, match="checksum"):
        session.import_layers(payload)
    for k, v in session.cache.items():
        np.testing.assert_array_equal(np.asarray(v), before[k], err_msg=k)

    # stale epoch: envelope from another point in time is refused
    stale, _ = session.export_layers(0, 2)
    epoch, pos, crc = stale[HANDOFF_META_KEY]
    stale[HANDOFF_META_KEY] = (epoch + 1, pos, crc)
    with pytest.raises(HandoffCorrupted, match="stale"):
        session.import_layers(stale)

    # an intact payload still round-trips after the rejections
    clean, _ = session.export_layers(0, 2)
    session.import_layers(clean)
    mgr.close()


def test_corrupt_handoff_falls_back_to_recompute():
    mgr, session = _tiny_stateful(force_mode="transfer")
    mgr.active.process()
    mgr.pool.fault_plan = faults("handoff_corrupt(p=1.0)").arm()
    with pytest.warns(HandoffIntegrityWarning):
        mgr.repartition("switch_b2", 2)
    h = mgr.pool.handoffs[-1]
    assert h.fallback and h.mode == "recompute"
    out, _ = mgr.active.process()            # recovered state still decodes
    assert np.isfinite(np.asarray(out)).all()
    mgr.close()


def test_corrupt_batch_handoff_falls_back_to_per_slot_recompute():
    """A corrupted whole-batch hand-off (slot pool, several ragged
    sessions in flight) is detected by the integrity envelope and every
    slot is rebuilt by the masked fixed-shape recompute — bit-identical
    per slot to a pool that never switched."""
    from repro.serving import make_session_manager
    cfg = dataclasses.replace(get_config("qwen2.5-3b").reduced(),
                              num_layers=2)
    mgr, sm = make_session_manager(cfg, split=2, net=NetworkModel(1000.0),
                                   num_slots=3, max_seq=32, seed=0,
                                   force_mode="transfer")
    rng = np.random.default_rng(11)
    sids = [sm.admit(rng.integers(0, cfg.vocab_size,
                                  size=n).astype(np.int32))
            for n in (4, 7, 5)]
    mgr.active.process()
    snap = sm.snapshot()
    mgr.active.process()                     # control arm: no switch
    control = {s: (sm.logits_for(s), sm.tokens_for(s)) for s in sids}
    sm.restore(snap)

    mgr.pool.fault_plan = faults("handoff_corrupt(p=1.0)").arm()
    with pytest.warns(HandoffIntegrityWarning):
        mgr.repartition("switch_b2", 1)
    h = mgr.pool.handoffs[-1]
    assert h.fallback and h.mode == "recompute"
    mgr.active.process()
    assert set(sm.session_ids()) == set(sids)    # zero dropped
    for s in sids:
        logits, toks = control[s]
        np.testing.assert_array_equal(sm.logits_for(s), logits, err_msg=s)
        np.testing.assert_array_equal(sm.tokens_for(s), toks, err_msg=s)
    mgr.close()
