"""Input-spec coverage: every runnable (arch x shape) pair builds its
ShapeDtypeStruct stand-ins (what the dry-run lowers against) — no device
allocation, so the full 39-pair sweep runs in seconds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,
                           get_shape, pair_is_runnable)
from repro.models import transformer as T
from repro.models.specs import input_specs

PAIRS = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES
         if pair_is_runnable(a, s)[0]]


def test_exactly_39_runnable_pairs():
    assert len(PAIRS) == 39      # 40 minus whisper x long_500k (DESIGN.md s4)


@pytest.mark.parametrize("arch,shape_name", PAIRS)
def test_input_specs_build(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs, cache = input_specs(cfg, shape, dtype=jnp.bfloat16)
    assert "tokens" in specs or "token" in specs
    if shape.kind == "train":
        assert specs["tokens"].shape == specs["labels"].shape
        assert specs["tokens"].shape[0] == shape.global_batch
    if shape.kind == "decode":
        assert cache is not None
        assert specs["token"].shape == (shape.global_batch, 1)
        # ring cache never exceeds the effective window
        w = T.effective_window(cfg, shape.seq_len)
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            if leaf.ndim == 5:        # (L, B, KH, CL, hd)
                assert leaf.shape[3] <= (w or shape.seq_len)
    if cfg.frontend == "vision" and shape.kind != "decode":
        assert specs["vision_embeds"].shape[1] == cfg.frontend_tokens
        # frontend tokens are carved out of seq_len
        assert specs["tokens"].shape[-1] + cfg.frontend_tokens == shape.seq_len


def test_effective_window_policy():
    mix = get_config("mixtral-8x22b")
    yi = get_config("yi-34b")
    assert T.effective_window(mix, 4096) == 4096         # native SWA always
    assert T.effective_window(yi, 32_768) is None        # full attention
    assert T.effective_window(yi, 524_288) == 8192       # swa-variant kicks in


def test_long500k_cache_fits_v5e():
    """The ring caches that long_500k decodes against must fit 16 GB chips
    after sharding (256-way worst case bound: total/256 < 16 GiB)."""
    for arch in ("zamba2-7b", "falcon-mamba-7b", "mixtral-8x22b", "yi-34b"):
        cfg = get_config(arch)
        shape = get_shape("long_500k")
        _, cache = input_specs(cfg, shape, dtype=jnp.bfloat16)
        total = sum(np.prod(l.shape) * l.dtype.itemsize
                    for l in jax.tree.leaves(cache))
        assert total / 256 < 16 * 2**30, arch
