"""Strategy registry, PipelinePool, switch_pool, and controller policies."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import (BandwidthTrace, CooldownPolicy, HysteresisPolicy,
                        ImmediatePolicy, NetworkModel, NeukonfigController,
                        PipelineManager, PipelinePool, StageRunner, get_policy)
from repro.core.pipeline import EdgeCloudPipeline
from repro.core.profiler import ModelProfile, UnitProfile
from repro.core.strategies import (StandbySplitMismatch, SwitchReport,
                                   SwitchStrategy, available_strategies,
                                   benchmark_specs, get_strategy, parse_spec,
                                   register_strategy, unregister_strategy)
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-3b").reduced()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    runner = StageRunner(cfg, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    return cfg, runner, {"tokens": toks}


def _mgr(runner, inputs, **kw):
    return PipelineManager(runner, split=1, net=NetworkModel(20.0),
                           sample_inputs=inputs, **kw)


def _param_bytes(runner):
    return sum(a.size * a.dtype.itemsize
               for a in jax.tree.leaves(runner.params))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_contains_paper_strategies():
    assert {"pause_resume", "switch_a", "switch_b1", "switch_b2",
            "switch_pool"} <= set(available_strategies())


def test_registry_unknown_name_errors():
    with pytest.raises(KeyError, match="unknown strategy"):
        get_strategy("no_such_strategy")


def test_registry_parameterized_spec():
    s = get_strategy("switch_pool(k=2)")
    assert s.k == 2 and s.spec == "switch_pool(k=2)"
    assert parse_spec("switch_pool(k=2, owns_weights=False)") == \
        ("switch_pool", {"k": 2, "owns_weights": False})
    with pytest.raises(ValueError, match="key=value"):
        parse_spec("switch_pool(2)")


def test_registry_rejects_duplicates():
    @register_strategy("_dup_probe")
    class A(SwitchStrategy):
        pass
    try:
        with pytest.raises(ValueError, match="already registered"):
            @register_strategy("_dup_probe")
            class B(SwitchStrategy):
                pass

        @register_strategy("_dup_probe", override=True)
        class C(SwitchStrategy):
            pass
        assert get_strategy("_dup_probe").__class__ is C
    finally:
        unregister_strategy("_dup_probe")
    assert "_dup_probe" not in available_strategies()


def test_custom_strategy_plugs_in_without_core_edits(setup):
    """Extensibility proof: a @register_strategy class is reachable through
    PipelineManager (and therefore controller/benchmarks) by name alone."""
    cfg, runner, inputs = setup

    @register_strategy("test_noop")
    class NoopStrategy(SwitchStrategy):
        def switch(self, pool, new_split):
            old = pool.active.split
            return SwitchReport("test_noop", old, old, downtime=0.0)

    try:
        assert "test_noop" in available_strategies()
        assert "test_noop" in benchmark_specs()
        mgr = _mgr(runner, inputs)
        rep = mgr.repartition("test_noop", 2)
        assert rep.strategy == "test_noop" and rep.downtime == 0.0
    finally:
        unregister_strategy("test_noop")


# ---------------------------------------------------------------------------
# pipeline pool
# ---------------------------------------------------------------------------

def test_pool_warm_reuse_and_keying(setup):
    cfg, runner, inputs = setup
    pool = PipelinePool(runner, NetworkModel(20.0), inputs)
    e1, hit1 = pool.ensure(1)
    pool.activate(e1.key)
    e2, hit2 = pool.ensure(1)                 # same key -> cache hit
    assert not hit1 and hit2 and e2 is e1
    e3, hit3 = pool.ensure(1, owns_weights=True, cold=True)
    assert not hit3 and e3 is not e1          # distinct key per weight mode
    assert pool.has(1) and pool.has(1, True)


def test_pool_lru_eviction_under_budget(setup):
    cfg, runner, inputs = setup
    pbytes = _param_bytes(runner)
    pool = PipelinePool(runner, NetworkModel(20.0), inputs,
                        mem_budget_bytes=int(1.5 * pbytes))
    e, _ = pool.ensure(1)
    pool.activate(e.key)
    pool.ensure(2, owns_weights=True, cold=True, reuse=False)
    assert pool.has(2, True)
    pool.ensure(0, owns_weights=True, cold=True, reuse=False)
    # two owned standbys (2x) exceed the 1.5x budget -> LRU (split 2) evicted
    assert pool.has(0, True) and not pool.has(2, True)
    assert pool.additional_bytes() <= int(1.5 * pbytes)
    # the active pipeline is never evictable
    with pytest.raises(ValueError):
        pool.release(pool.active_key)


def test_pool_shared_weight_entries_are_free(setup):
    cfg, runner, inputs = setup
    pool = PipelinePool(runner, NetworkModel(20.0), inputs,
                        mem_budget_bytes=0)
    e, _ = pool.ensure(1)
    pool.activate(e.key)
    pool.ensure(2)                            # shares donor weights: 0 bytes
    assert pool.has(2) and pool.additional_bytes() == 0


# ---------------------------------------------------------------------------
# bugfixes: pause_resume outage + switch_a mismatch surfacing
# ---------------------------------------------------------------------------

def test_pause_resume_failure_restores_service(setup, monkeypatch):
    """A failed cold rebuild must not leave the service down forever."""
    cfg, runner, inputs = setup
    mgr = _mgr(runner, inputs)
    ref, _ = mgr.serve(inputs)
    def broken_build(*a, **kw):
        raise RuntimeError("model storage unreachable")

    monkeypatch.setattr(EdgeCloudPipeline, "build", broken_build)
    with pytest.raises(RuntimeError, match="storage unreachable"):
        mgr.repartition("pause_resume", 2)
    out, _ = mgr.serve(inputs)                # old pipeline restored
    assert jnp.max(jnp.abs(out - ref)) < 1e-4
    assert mgr.active.split == 1


def test_switch_a_surfaces_standby_mismatch(setup):
    cfg, runner, inputs = setup
    mgr = _mgr(runner, inputs, standby_split=2)
    with pytest.warns(StandbySplitMismatch, match="standby built for split 2"):
        rep = mgr.repartition("switch_a", 0)  # standby was built for 2
    assert rep.new_split == 2 and rep.note    # switched to what exists
    assert mgr.active.split == 2
    mgr.drain()                               # settle the standby rebuild


# ---------------------------------------------------------------------------
# switch_pool: k=0 == B2, k=1 == A, memory scales with k
# ---------------------------------------------------------------------------

def test_switch_pool_k1_equivalent_to_scenario_a(setup):
    cfg, runner, inputs = setup
    mgr = _mgr(runner, inputs)
    reps = [mgr.repartition("switch_pool(k=1)", s) for s in (2, 1, 2, 1)]
    assert not reps[0].cache_hit and reps[0].t_build > 0   # first: cold miss
    for rep in reps[1:]:                      # steady: pure pointer swap
        assert rep.cache_hit and rep.t_build == 0
        assert rep.downtime < reps[0].downtime
        assert not rep.full_outage
    mem = mgr.memory_report()                 # A Case 1 memory: 2x
    assert mem["additional_bytes"] == pytest.approx(mem["initial_bytes"],
                                                    rel=0.01)
    out, _ = mgr.serve(inputs)                # service alive on the standby
    assert out.shape[-1] == cfg.vocab_size


def test_switch_pool_k0_equivalent_to_b2(setup):
    cfg, runner, inputs = setup
    mgr = _mgr(runner, inputs)
    reps = [mgr.repartition("switch_pool(k=0)", s) for s in (2, 1, 2)]
    for rep in reps:                          # always the warm-build path
        assert not rep.cache_hit and rep.t_build > 0
        assert not rep.full_outage
    assert mgr.memory_report()["additional_bytes"] == 0   # B2 memory: 1x
    rep_b2 = mgr.repartition("switch_b2", 1)
    assert rep_b2.t_build > 0                 # same mechanism as B2


def test_strategies_survive_zero_budget(setup):
    """A budget must never evict the pipeline a strategy is activating:
    owned-weight builds (B1, standby) still switch, just without retention."""
    cfg, runner, inputs = setup
    mgr = _mgr(runner, inputs, mem_budget_bytes=0)
    rep = mgr.repartition("switch_b1", 2)     # owned build, activated at once
    assert mgr.active.split == 2 and not rep.full_outage
    mgr.build_standby(1)                      # over budget but usable now
    assert mgr.standby is not None and mgr.standby.ready
    rep = mgr.repartition("switch_a", 1)
    assert mgr.active.split == 1 and rep.downtime < 0.05
    out, _ = mgr.serve(inputs)
    assert out.shape[-1] == cfg.vocab_size
    mgr.drain()                               # settle the standby rebuild


def test_switch_pool_respects_memory_budget(setup):
    """Budget 0 -> speculation evicted immediately -> behaves like k=0."""
    cfg, runner, inputs = setup
    mgr = _mgr(runner, inputs, mem_budget_bytes=0)
    reps = [mgr.repartition("switch_pool(k=1)", s) for s in (2, 1, 2)]
    assert all(not r.cache_hit for r in reps)
    mgr.drain()           # let trailing speculation land and be evicted
    assert mgr.memory_report()["additional_bytes"] == 0


# ---------------------------------------------------------------------------
# controller policies
# ---------------------------------------------------------------------------

def _toy_profile():
    """Optimum flips between split 1 (20 Mbps) and split 3 (0.5 Mbps)."""
    units = [UnitProfile("embed", 0, 0, 400_000)]
    units += [UnitProfile(f"l{i}", 0.05, 0.001, b)
              for i, b in enumerate([200_000, 100_000, 50_000])]
    units += [UnitProfile("head", 0.05, 0.001, 0)]
    return ModelProfile("toy", units)


def test_policy_objects_decide_on_gain():
    profile = _toy_profile()
    net = NetworkModel(0.5)
    from repro.core.partitioner import optimal_split
    best = optimal_split(profile, net)
    assert best.split != 1
    cur = profile.total_latency(1, net)
    gain = (cur - best.total) / cur
    kw = dict(current_split=1, best=best, profile=profile, net=net)
    assert ImmediatePolicy().should_switch(0.0, **kw)
    assert HysteresisPolicy(min_gain=gain / 2).should_switch(0.0, **kw)
    assert not HysteresisPolicy(min_gain=gain * 2).should_switch(0.0, **kw)
    cd = CooldownPolicy(cooldown_s=10.0)
    assert cd.should_switch(0.0, **kw)
    cd.notify_switched(0.0)
    assert not cd.should_switch(5.0, **kw)
    assert cd.should_switch(10.0, **kw)
    # no-op when the optimum did not move
    kw["current_split"] = best.split
    assert not ImmediatePolicy().should_switch(0.0, **kw)


def test_policy_spec_resolution():
    p = get_policy("cooldown(cooldown_s=3.0)")
    assert isinstance(p, CooldownPolicy) and p.cooldown_s == 3.0
    assert isinstance(get_policy("immediate"), ImmediatePolicy)
    with pytest.raises(KeyError, match="unknown policy"):
        get_policy("nope")


def test_controller_cooldown_rate_limits_flapping(setup):
    cfg, runner, inputs = setup
    flappy = BandwidthTrace(steps=[(0, 20.0)] + [(i, 0.5 if i % 2 else 20.0)
                                                 for i in range(1, 12)])
    mgr_i = _mgr(runner, inputs)
    ctl_i = NeukonfigController(mgr_i, _toy_profile(), flappy,
                                strategy="switch_b2", policy="immediate")
    n_imm = sum(1 for e in ctl_i.run(11.0) if e.report)
    mgr_c = _mgr(runner, inputs)
    ctl_c = NeukonfigController(mgr_c, _toy_profile(), flappy,
                                strategy="switch_b2",
                                policy=CooldownPolicy(cooldown_s=6.0))
    n_cd = sum(1 for e in ctl_c.run(11.0) if e.report)
    assert n_imm > n_cd >= 1


def test_controller_hysteresis_suppresses_marginal_gain(setup):
    cfg, runner, inputs = setup
    trace = BandwidthTrace(steps=[(0.0, 20.0), (3.0, 0.5)])
    mgr = _mgr(runner, inputs)
    ctl = NeukonfigController(mgr, _toy_profile(), trace,
                              strategy="switch_b2",
                              policy=HysteresisPolicy(min_gain=2.0))
    assert all(e.report is None for e in ctl.run(8.0))
    assert mgr.active.split == 1              # never switched


def test_controller_auto_prepares_strategy(setup):
    """The controller owns the prepare() lifecycle: switch_a works without a
    manually-built standby, pre-positioned for the trace's operating points."""
    cfg, runner, inputs = setup
    trace = BandwidthTrace(steps=[(0.0, 20.0), (3.0, 0.5)])
    mgr = _mgr(runner, inputs)                # note: no standby_split
    ctl = NeukonfigController(mgr, _toy_profile(), trace,
                              strategy="switch_a")
    assert mgr.standby is not None and mgr.standby.ready
    events = [e for e in ctl.run(5.0) if e.report]
    assert len(events) == 1 and events[0].report.cache_hit


def test_controller_drives_switch_pool_predictively(setup):
    """Through the controller, switch_pool learns the trace: the second
    bandwidth change lands on a pre-built pipeline (Scenario-A downtime)."""
    cfg, runner, inputs = setup
    trace = BandwidthTrace(steps=[(0.0, 20.0), (3.0, 0.5), (6.0, 20.0)])
    mgr = _mgr(runner, inputs)
    ctl = NeukonfigController(mgr, _toy_profile(), trace,
                              strategy="switch_pool(k=1)",
                              candidate_splits=())   # cold start: must learn
    events = [e for e in ctl.run(9.0) if e.report]
    assert len(events) == 2
    assert not events[0].report.cache_hit     # first move: unseen optimum
    assert events[1].report.cache_hit         # predicted from the trend
    assert events[1].report.downtime < events[0].report.downtime
