"""NEUKONFIG system behaviour: pipeline correctness, switching strategies,
downtime semantics (the paper's central claims as invariants)."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:              # clean env: deterministic fallback sampler
    from _hypothesis_compat import hypothesis, st

from repro.configs import get_config
from repro.core.downtime import simulate_window, sweep_fps
from repro.core.network import (BandwidthTrace, NetworkModel, NetworkMonitor,
                                PAPER_TRACE)
from repro.core.pipeline import EdgeCloudPipeline
from repro.core.stages import StageRunner
from repro.core.switching import PipelineManager
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-3b").reduced()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    runner = StageRunner(cfg, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0,
                              cfg.vocab_size)
    return cfg, runner, {"tokens": toks}


def test_pipeline_equals_monolithic_any_split(setup):
    """THE correctness invariant: a partitioned model computes the same
    function as the unpartitioned one, for every split point."""
    cfg, runner, inputs = setup
    ref = runner.run_units(inputs, 0, runner.num_units)["logits"]
    for split in range(runner.num_units - 1):
        mid = runner.run_units(inputs, 0, split + 1)
        out = runner.run_units(mid, split + 1, runner.num_units)["logits"]
        assert jnp.max(jnp.abs(out - ref)) < 1e-4, f"split {split}"


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-7b",
                                  "whisper-medium", "mixtral-8x22b",
                                  "internvl2-76b"])
def test_pipeline_equals_monolithic_other_families(arch, rng):
    cfg = get_config(arch).reduced()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    runner = StageRunner(cfg, params)
    inputs = {"tokens": jax.random.randint(rng, (1, 12), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        inputs["vision_embeds"] = jax.random.normal(
            rng, (1, cfg.frontend_tokens, cfg.d_model)) * 0.02
    if cfg.frontend == "audio":
        inputs["frames"] = jax.random.normal(
            rng, (1, cfg.encoder.context_len, cfg.d_model)) * 0.02
    ref = runner.run_units(inputs, 0, runner.num_units)["logits"]
    for split in [0, runner.num_units // 2, runner.num_units - 2]:
        mid = runner.run_units(inputs, 0, split + 1)
        out = runner.run_units(mid, split + 1, runner.num_units)["logits"]
        assert jnp.max(jnp.abs(out - ref)) < 1e-3, f"{arch} split {split}"


def test_pipeline_serves_other_shapes_via_retrace_fallback(setup):
    """AOT stage executables are specialized to the sample avals; a request
    with a different shape must fall back to the retracing warm path, not
    raise."""
    cfg, runner, inputs = setup
    mgr = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                          sample_inputs=inputs)
    other = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (1, 40), 0,
                                          cfg.vocab_size)}
    out, _ = mgr.serve(other)
    assert out.shape[:2] == (1, 40)
    ref = runner.run_units(other, 0, runner.num_units)["logits"]
    assert jnp.max(jnp.abs(out - ref)) < 1e-4
    out2, _ = mgr.serve(inputs)          # the original shape still serves
    assert out2.shape[1] == inputs["tokens"].shape[1]


def test_switch_preserves_service_output(setup):
    """After any repartition the pipeline must still compute the same
    function (only the split moved)."""
    cfg, runner, inputs = setup
    mgr = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                          sample_inputs=inputs, standby_split=2)
    ref, _ = mgr.serve(inputs)
    for strat, split in [("switch_a", 2), ("switch_b1", 0),
                         ("switch_b2", 2), ("pause_resume", 1)]:
        mgr.repartition(strat, split)
        out, _ = mgr.serve(inputs)
        assert jnp.max(jnp.abs(out - ref)) < 1e-4, strat


def test_downtime_ordering(setup):
    """Paper Figs. 11-13: t(A) << t(B2), and the baseline is a FULL outage
    while dynamic switching keeps serving."""
    cfg, runner, inputs = setup
    mgr = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                          sample_inputs=inputs, standby_split=2)
    rep_a = mgr.repartition("switch_a", 2)
    rep_b2 = mgr.repartition("switch_b2", 0)
    rep_pr = mgr.repartition("pause_resume", 2)
    rep_b1 = mgr.repartition("switch_b1", 1)
    assert rep_a.downtime < rep_b2.downtime
    assert rep_a.downtime < 0.05          # paper: < 1 ms on their testbed
    assert rep_pr.full_outage and not rep_b1.full_outage
    assert not rep_a.full_outage and not rep_b2.full_outage
    # baseline must reload weights from storage; dynamic switching must not
    assert rep_pr.build_detail.t_weights > 0


def test_switch_b2_warm_cache_faster_than_cold(setup):
    """Scenario B Case 2 (same container) beats Case 1 (new container) when
    the configuration was seen before — the paper's t_exec < t_init."""
    cfg, runner, inputs = setup
    mgr = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                          sample_inputs=inputs)
    mgr.repartition("switch_b2", 2)     # warm the (0..2] stages
    rep_b1 = mgr.repartition("switch_b1", 1)
    mgr.repartition("switch_b2", 2)
    rep_b2 = mgr.repartition("switch_b2", 1)   # split 1 stages warm again
    assert rep_b2.downtime < rep_b1.downtime


def test_memory_tradeoff_table(setup):
    """Table I: standby-with-own-weights (A Case 1) doubles memory; shared
    weights (Case 2) do not."""
    cfg, runner, inputs = setup
    mgr1 = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                           sample_inputs=inputs, standby_split=2,
                           standby_owns_weights=True)
    m1 = mgr1.memory_report()
    assert m1["additional_bytes"] == pytest.approx(m1["initial_bytes"], rel=0.01)
    mgr2 = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                           sample_inputs=inputs, standby_split=2,
                           standby_owns_weights=False)
    m2 = mgr2.memory_report()
    assert m2["additional_bytes"] == 0
    assert m2["total_bytes"] == m2["initial_bytes"]


def test_monitor_detects_paper_trace():
    mon = NetworkMonitor(PAPER_TRACE)
    events = [t for t in np.arange(0, 90, 1.0) if mon.poll(float(t))]
    assert len(events) == 2          # 20->5 at t=30, 5->20 at t=60
    assert events[0] == pytest.approx(30, abs=1) \
        and events[1] == pytest.approx(60, abs=1)


def test_monitor_hysteresis_suppresses_flapping():
    trace = BandwidthTrace(steps=[(0, 20)] + [(i, 20 if i % 2 else 5)
                                              for i in range(1, 20)])
    mon = NetworkMonitor(trace, hysteresis_s=5.0)
    events = [t for t in np.arange(0, 20, 1.0) if mon.poll(float(t))]
    assert len(events) <= 4


# ---------------------------------------------------------------------------
# frame-drop simulator (Figs. 14-15 semantics)
# ---------------------------------------------------------------------------

def test_pause_resume_drops_everything():
    r = simulate_window(fps=30, window=6.0, service_time=0.01,
                        full_outage=True)
    assert r.drop_rate == 1.0            # paper: "no frames ... processed"


def test_dynamic_switching_serves_during_window():
    r = simulate_window(fps=30, window=6.0, service_time=0.01,
                        full_outage=False)
    assert 0.0 <= r.drop_rate < 1.0
    assert r.served > 0


@hypothesis.given(st.floats(1, 60), st.floats(0.001, 2.0))
@hypothesis.settings(deadline=None, max_examples=30)
def test_drop_rate_monotone_in_fps(window, service_time):
    """Paper: 'more frames are dropped as the incoming frame rates increase'."""
    rates = [simulate_window(fps=f, window=window, service_time=service_time,
                             full_outage=False).drop_rate
             for f in (1, 5, 15, 30)]
    assert all(b >= a - 0.15 for a, b in zip(rates, rates[1:]))


def test_zero_window_drops_nothing():
    r = simulate_window(fps=30, window=0.0, service_time=1e-5,
                        full_outage=False, horizon=1.0)
    assert r.dropped == 0
