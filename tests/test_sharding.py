"""Sharded cloud stage: sharding rules on fake multi-device CPU meshes,
the first-class PipelineKey API, and mesh-shape-changing repartitions
(SimPool: every registered strategy; real pipelines: logits parity and
reshard accounting).

The device-hungry cases need the process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and skip
otherwise: the flag is deliberately NOT set suite-wide (it changes XLA
CPU numerics enough to break the bit-exact split-invariance tests), so
``ci.sh`` runs this module a second time in its own flagged process."""
import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.core import NetworkModel, PipelineManager, StageRunner
from repro.core.pool import PipelineKey, PoolKey
from repro.core.strategies import available_strategies
from repro.distributed import (ShardingDegraded, cache_shardings,
                               decode_state_shardings, input_shardings,
                               param_shardings)
from repro.launch.mesh import make_cloud_mesh
from repro.models import transformer as T
from repro.serving.sim import SimPool, SimRunner

needs_devices = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "in the environment before jax initialises (ci.sh runs this "
           "module that way in a dedicated process)")


def _spec_of(shardings, path_suffix: str):
    """PartitionSpec of the first leaf whose joined path ends with suffix."""
    for path, sh in jax.tree_util.tree_flatten_with_path(shardings)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", "")))
                        for p in path)
        if name.endswith(path_suffix):
            return sh.spec
    raise KeyError(path_suffix)


# ---------------------------------------------------------------------------
# PipelineKey API (satellite: first-class pool keys)
# ---------------------------------------------------------------------------

def test_pipeline_key_frozen_and_normalized():
    k = PipelineKey(split=3, mesh_shape=[2, 4])
    assert k.mesh_shape == (2, 4) and isinstance(k.mesh_shape, tuple)
    assert k.owns_weights is False and k.variant == ""
    with pytest.raises(dataclasses.FrozenInstanceError):
        k.split = 5
    assert PoolKey is PipelineKey          # deprecated alias still imports


def test_pipeline_key_legacy_tuple_shim():
    with pytest.warns(DeprecationWarning, match="tuple pool keys"):
        k = PipelineKey.of((2, True))
    assert k == PipelineKey(split=2, owns_weights=True, mesh_shape=None)
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # passthrough must not warn
        assert PipelineKey.of(k) is k
    with pytest.raises(TypeError, match="not a pool key"):
        PipelineKey.of("nope")


def test_pool_make_key_fills_default_mesh():
    pool = SimPool(SimRunner(8), NetworkModel(20.0))
    try:
        assert pool.make_key(1).mesh_shape is None
        pool.set_mesh_shape((2,))
        assert pool.make_key(1).mesh_shape == (2,)
        # explicit always wins over the pool default — including an
        # explicit "no mesh"
        assert pool.make_key(1, mesh_shape=(4,)).mesh_shape == (4,)
        assert pool.make_key(1, mesh_shape=None).mesh_shape is None
    finally:
        pool.close()


def test_pool_accepts_legacy_tuple_keys():
    pool = SimPool(SimRunner(8), NetworkModel(20.0))
    try:
        entry, _ = pool.ensure(PipelineKey(split=2, owns_weights=True))
        with pytest.warns(DeprecationWarning, match="tuple pool keys"):
            assert pool.has((2, True))
        with pytest.warns(DeprecationWarning, match="tuple pool keys"):
            pool.release((2, True))
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# sharding rules on fake 2/4/8-device meshes
# ---------------------------------------------------------------------------

@needs_devices
@pytest.mark.parametrize("mesh_shape", [(2,), (4,), (8,), (2, 4)])
@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen2-moe-a2.7b",
                                  "falcon-mamba-7b"])
def test_param_shardings_divide_on_real_meshes(arch, mesh_shape):
    """dense/GQA, moe and ssm params all get axis-dividing shardings on
    every CI mesh (the jit-argument requirement the guard enforces)."""
    cfg = get_config(arch)
    mesh = make_cloud_mesh(mesh_shape)
    ps = jax.eval_shape(
        functools.partial(T.init_model, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", ShardingDegraded)
        sh = param_shardings(cfg, mesh, ps, shard_fsdp=False)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for leaf, s in zip(jax.tree.leaves(ps), jax.tree.leaves(sh)):
        for dim, ax in enumerate(s.spec):
            if ax is None:
                continue
            n = int(np.prod([sizes[a] for a in
                             (ax if isinstance(ax, tuple) else (ax,))]))
            assert leaf.shape[dim] % n == 0, (s.spec, leaf.shape)


@needs_devices
def test_param_shardings_use_model_axis():
    cfg = get_config("qwen2.5-3b")
    mesh = make_cloud_mesh((4,))
    ps = jax.eval_shape(
        functools.partial(T.init_model, cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    sh = param_shardings(cfg, mesh, ps, shard_fsdp=False)
    assert _spec_of(sh, "wq")[-1] == "model"        # column-parallel
    assert _spec_of(sh, "wo")[-2] == "model"        # row-parallel
    assert "model" in _spec_of(sh, "embed")


@needs_devices
def test_param_shardings_guard_warns_not_silent():
    """A dim that does not divide the axis degrades to replication WITH a
    structured warning naming the leaf (was: silent replication)."""
    cfg = get_config("qwen2.5-3b")
    mesh = make_cloud_mesh((4,))
    odd = {"layers": {"attn": {"wq": jax.ShapeDtypeStruct((64, 13),
                                                          jnp.bfloat16)}}}
    with pytest.warns(ShardingDegraded, match=r"wq\[dim 1\]=13"):
        sh = param_shardings(cfg, mesh, odd, shard_fsdp=False)
    assert _spec_of(sh, "wq") == jax.sharding.PartitionSpec(None, None)


@needs_devices
def test_input_and_cache_shardings_on_2d_mesh():
    cfg = get_config("qwen2.5-3b")
    mesh = make_cloud_mesh((2, 4))
    shape = INPUT_SHAPES["decode_32k"]
    inp = {"tokens": jax.ShapeDtypeStruct((shape.global_batch, 1),
                                          jnp.int32)}
    ish = input_shardings(cfg, mesh, inp, shape)
    assert ish["tokens"].spec[0] == "data"          # batch -> dp
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, 128,
                             dtype=jnp.bfloat16))
    csh = cache_shardings(cfg, mesh, cache, shape)
    assert jax.tree.structure(csh) == jax.tree.structure(cache)


@needs_devices
def test_decode_state_shardings_rules():
    """Live-session layouts: kv heads -> tp when divisible, head_dim for
    GQA, conv channel dim, ssm channel dim; dp always replicated."""
    cfg = get_config("qwen2.5-3b")
    mesh = make_cloud_mesh((4,))
    st = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    state = {
        "k0": st(1, 8, 64, 128),      # KH=8 divides tp=4 -> dim 1
        "v1": st(1, 2, 64, 128),      # GQA KH=2: falls to head_dim dim 3
        "conv0": st(1, 3, 256),       # channels (last dim) -> tp
        "ssm0": st(1, 256, 16),       # mamba channel dim 1 -> tp
    }
    sh = decode_state_shardings(cfg, mesh, state)
    P = jax.sharding.PartitionSpec
    assert sh["k0"].spec == P(None, "model", None, None)
    assert sh["v1"].spec == P(None, None, None, "model")
    assert sh["conv0"].spec == P(None, None, "model")
    assert sh["ssm0"].spec == P(None, "model", None)


@needs_devices
def test_decode_state_shardings_degrade_warns():
    cfg = get_config("qwen2.5-3b")
    mesh = make_cloud_mesh((4,))
    state = {"k0": jax.ShapeDtypeStruct((1, 3, 64, 7), jnp.float32)}
    with pytest.warns(ShardingDegraded, match="k0"):
        sh = decode_state_shardings(cfg, mesh, state)
    assert sh["k0"].spec == jax.sharding.PartitionSpec(None, None, None,
                                                       None)


# ---------------------------------------------------------------------------
# mesh-shape-changing repartitions: every registered strategy (SimPool)
# ---------------------------------------------------------------------------

def test_mesh_change_recorded_by_every_strategy():
    """set_mesh_shape + repartition (any strategy) -> the switch report
    carries the resharding wall and the mesh transition."""
    for name in sorted(available_strategies()):
        pool = SimPool(SimRunner(8), NetworkModel(20.0))
        mgr = PipelineManager(pool.runner, split=1, net=pool.net,
                              sample_inputs=None, pool=pool)
        try:
            mgr.set_mesh_shape((2,))
            mgr.build_standby(2)       # switch_a needs a live standby
            rep = mgr.repartition(name, 2)
            assert rep.old_mesh is None and rep.new_mesh == (2,), name
            assert rep.mesh_change and rep.t_reshard >= 0.0, name
            assert pool.reshards and \
                pool.reshards[-1].new_mesh == (2,), name
            # same mesh back-switch: no transition recorded
            rep2 = mgr.repartition(name if name != "switch_a"
                                   else "switch_b1", 1)
            assert not rep2.mesh_change and rep2.t_reshard == 0.0, name
        finally:
            mgr.close()


# ---------------------------------------------------------------------------
# real pipelines: sharded-vs-single-device parity + reshard accounting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("qwen2.5-3b").reduced()
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    runner = StageRunner(cfg, params)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                         cfg.vocab_size))
    return runner, {"tokens": toks}


@needs_devices
def test_sharded_logits_match_single_device(tiny):
    runner, inputs = tiny
    mgr = PipelineManager(runner, split=1, net=NetworkModel(20.0),
                          sample_inputs=inputs)
    try:
        ref, _ = mgr.serve(inputs)
        mgr.set_mesh_shape((2,))
        rep = mgr.repartition("switch_b2", 1)
        assert rep.mesh_change and rep.new_mesh == (2,)
        assert rep.t_reshard >= 0.0
        out, _ = mgr.serve(inputs)
        # all-reduce reorders float sums: numerical, not bit, equality
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
    finally:
        mgr.close()


@needs_devices
def test_stateful_mesh_roundtrip_decodes_identically():
    """Decode streams with and without a mid-stream hop onto a 2-way mesh
    (and back) must emit the same tokens; both mesh transitions record a
    reshard on their reports."""
    from repro.core.stateful import make_stateful_manager
    cfg = get_config("qwen2.5-3b").reduced()
    net = NetworkModel(50.0)

    mgr, sess = make_stateful_manager(cfg, split=1, net=net, prompt_len=8,
                                      max_seq=32, seed=3)
    try:
        ref = [np.asarray(mgr.serve(None)[0]) for _ in range(4)]
        ref_toks = sess.tokens.copy()
    finally:
        mgr.close()

    mgr, sess = make_stateful_manager(cfg, split=1, net=net, prompt_len=8,
                                      max_seq=32, seed=3)
    try:
        out = [np.asarray(mgr.serve(None)[0])]
        mgr.set_mesh_shape((2,))
        r1 = mgr.repartition("switch_b2", 1)
        out.append(np.asarray(mgr.serve(None)[0]))
        mgr.set_mesh_shape(None)
        r2 = mgr.repartition("switch_b2", 1)
        out += [np.asarray(mgr.serve(None)[0]) for _ in range(2)]
        toks = sess.tokens.copy()
    finally:
        mgr.close()

    assert r1.mesh_change and r1.new_mesh == (2,)
    assert r2.mesh_change and r2.old_mesh == (2,) and r2.new_mesh is None
    np.testing.assert_array_equal(toks, ref_toks)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
